"""Control plane (repro.core.plane): the single control-law code path.

Pins the tentpole refactor's contract from four sides: (1) the NRM's
control_step is BIT-FOR-BIT the pre-refactor stateful loop (transcribed
here as oracles), (2) a 1-tenant ControlPlane tracks an NRM, (3) the
heterogeneous lax.switch tick equals per-branch planes row by row, and
(4) whole-plane snapshots kill/resume across processes losslessly.
"""
import os
import pickle
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import PowerControlConfig
from repro.core import policies as pol
from repro.core.adaptive import RLSAdapter, RLSConfig
from repro.core.controller import PIController, PIGains
from repro.core.nrm import NRM
from repro.core.plane import ControlPlane, _bucket, plane_step
from repro.core.plant import PROFILES
from repro.core.policies import DutyCyclePolicy, OfflineRLPolicy, PIPolicy
from repro.core.signals import HeartbeatAggregator
from repro.core.workloads.detect import (DetectorConfig, detect_init,
                                         detect_step, detector_values)


def _beats(rng, rate, t, dt):
    n = int(rng.poisson(rate * dt))
    return [t - dt + (j + 0.5) * dt / max(n, 1) for j in range(n)]


# ---------------------------------------------------------------------------
# 1. NRM.control_step == the pre-refactor stateful loop, bit for bit
# ---------------------------------------------------------------------------

def test_control_step_matches_pre_refactor_pi_loop():
    """Default-PI control_step now routes through plane_step; the
    decision sequence must equal the old `controller.step` loop exactly
    (same Python-float arithmetic, no f32 re-rounding)."""
    cfg = PowerControlConfig(epsilon=0.12, plant_profile="gros")
    nrm = NRM(cfg)
    ctrl = PIController(PIGains.from_model(nrm.profile, 0.12))
    hb = HeartbeatAggregator()
    rng = np.random.default_rng(0)
    dt, t = cfg.sampling_period, 0.0
    for k in range(100):
        t += dt
        for bt in _beats(rng, 3.0 + 2.0 * (k % 7), t, dt):
            nrm.heartbeat(t=bt)
            hb.beat(bt)
        rec = nrm.control_step(dt=dt)
        p = hb.progress(t)  # consumes the window: call once per period
        pcap_ref = ctrl.step(p, dt)
        assert rec.pcap == pcap_ref, f"period {k}"
        assert rec.progress == p


@pytest.mark.parametrize("policy", [
    DutyCyclePolicy(), PIPolicy(adaptive=RLSConfig()),
    OfflineRLPolicy(weights=(0.3,) * pol.N_FEATURES)],
    ids=["dutycycle", "pi_rls", "offline_rl"])
def test_control_step_matches_pre_refactor_policy_loop(policy):
    """policy= + detector= control_step vs a transcription of the old
    body (detect -> on_change -> PolicyObs -> policy_step), bit for bit,
    across a phase change that fires the live detector."""
    det_cfg = DetectorConfig(threshold=6.0, min_gap=5)
    nrm = NRM(PowerControlConfig(epsilon=0.1, plant_profile="gros"),
              policy=policy, detector=det_cfg)
    prof, gains = nrm.profile, nrm.gains
    vals = pol.policy_values(policy, prof, gains)
    state = pol.policy_init(policy, vals, gains)
    det_vals = detector_values(det_cfg, prof)
    det_state = detect_init(det_vals, gains, float(prof.pcap_max))
    hb = HeartbeatAggregator()
    pcap_applied = float(prof.pcap_max)
    rng = np.random.default_rng(1)
    dt, t, fired = 1.0, 0.0, False
    for k in range(80):
        t += dt
        rate = 40.0 if k < 40 else 8.0  # mid-run phase change
        for bt in _beats(rng, rate, t, dt):
            nrm.heartbeat(t=bt)
            hb.beat(bt)
        rec = nrm.control_step(dt=dt)
        # --- transcribed pre-refactor control_step body ---
        progress = hb.progress(t)
        det_state, det = detect_step(det_vals, det_state,
                                     jnp.float32(progress),
                                     gains.linearize(pcap_applied),
                                     jnp.float32(dt))
        detected = bool(det)
        st = state
        if detected:
            st = pol.branch_on_change(policy)(vals, st)
        power = float(prof.power_of_pcap(pcap_applied))
        obs = pol.PolicyObs(progress=jnp.float32(progress),
                            power=jnp.float32(power), dt=jnp.float32(dt),
                            gains=gains,
                            phase_change=jnp.float32(detected))
        state, pcap = pol.policy_step(policy, vals, st, obs)
        pcap = float(pcap)
        # --------------------------------------------------
        assert rec.pcap == pcap, f"period {k}"
        assert rec.phase_change == detected
        fired = fired or detected
        pcap_applied = float(np.clip(pcap, prof.pcap_min, prof.pcap_max))
    assert fired, "detector never alarmed; the phase change is too mild"


def test_control_step_adaptive_tracks_numpy_adapter_oracle():
    """The default adaptive path moved from the float64 numpy RLSAdapter
    mirror onto the packed f32 pi_rls branch; trajectories must agree to
    estimator precision (not bit-for-bit: the old mirror was f64)."""
    nrm = NRM(PowerControlConfig(epsilon=0.1, plant_profile="gros",
                                 adaptive=True))
    adapter = RLSAdapter(nrm.gains, nrm.profile)
    ctrl = PIController(PIGains.from_model(nrm.profile, 0.1))
    hb = HeartbeatAggregator()
    rng = np.random.default_rng(2)
    dt, t = 1.0, 0.0
    mine, ref = [], []
    for k in range(60):
        t += dt
        for bt in _beats(rng, 30.0, t, dt):
            nrm.heartbeat(t=bt)
            hb.beat(bt)
        rec = nrm.control_step(dt=dt)
        progress = hb.progress(t)
        ctrl.gains = adapter.update(ctrl.gains, progress,
                                    float(ctrl.state.prev_pcap_l), dt)
        ref.append(ctrl.step(progress, dt))
        mine.append(rec.pcap)
    mine, ref = np.asarray(mine), np.asarray(ref)
    assert float(np.mean(np.abs(mine - ref)) / np.mean(np.abs(ref))) < 0.02
    # scheduled gains reach the observable controller state
    assert nrm.controller.gains.k_p == pytest.approx(
        float(nrm._rls_state.k_p))


# ---------------------------------------------------------------------------
# 2. ControlPlane vs NRM / vs itself
# ---------------------------------------------------------------------------

def test_plane_single_tenant_tracks_nrm():
    """One tenant's plane decisions track the NRM runtime loop (f32 row
    packing vs the NRM's Python-float gains: equal to float32 noise)."""
    plane = ControlPlane(profile="gros", epsilon=0.1, dt=1.0)
    plane.add_tenant("node0")
    nrm = NRM(PowerControlConfig(epsilon=0.1, plant_profile="gros"))
    rng = np.random.default_rng(3)
    t = 0.0
    for k in range(50):
        t += 1.0
        bts = _beats(rng, 3.0 + (k % 5), t, 1.0)
        if bts:
            plane.ingest(["node0"] * len(bts), bts)
            for bt in bts:
                nrm.heartbeat(t=bt)
        dec = plane.tick()
        rec = nrm.control_step(dt=1.0)
        s = plane.slot("node0")
        assert dec["progress"][s] == pytest.approx(rec.progress, abs=1e-5)
        assert dec["applied"][s] == pytest.approx(
            float(np.clip(rec.pcap, nrm.profile.pcap_min,
                          nrm.profile.pcap_max)), rel=1e-3)


def test_plane_many_pi_tenants_track_independent_nrms():
    """N tenants with different epsilons == N independent NRMs (the
    batched tick is N feedback loops, not one averaged one)."""
    plane = ControlPlane(profile="gros", dt=1.0)
    epss = [0.05, 0.1, 0.2]
    nrms = []
    for i, eps in enumerate(epss):
        plane.add_tenant(f"n{i}", epsilon=eps)
        nrms.append(NRM(PowerControlConfig(epsilon=eps,
                                           plant_profile="gros")))
    rng = np.random.default_rng(4)
    t = 0.0
    for k in range(40):
        t += 1.0
        ids, times = [], []
        for i, nrm in enumerate(nrms):
            bts = _beats(rng, 10.0 + 5.0 * i, t, 1.0)
            ids += [f"n{i}"] * len(bts)
            times += bts
            for bt in bts:
                nrm.heartbeat(t=bt)
        if ids:
            plane.ingest(ids, times)
        dec = plane.tick()
        for i, nrm in enumerate(nrms):
            rec = nrm.control_step(dt=1.0)
            s = plane.slot(f"n{i}")
            assert dec["applied"][s] == pytest.approx(
                float(np.clip(rec.pcap, nrm.profile.pcap_min,
                              nrm.profile.pcap_max)), rel=1e-3), \
                f"tenant {i} period {k}"


def test_heterogeneous_plane_matches_single_branch_planes():
    """Mixed policy kinds dispatch through one lax.switch graph; each
    row must compute exactly what a single-branch plane computes."""
    mk = dict(profile="gros", dt=1.0, detector=DetectorConfig())
    mixed = ControlPlane(**mk)
    policies = {"a": None, "b": DutyCyclePolicy(),
                "c": PIPolicy(adaptive=RLSConfig()),
                "d": OfflineRLPolicy(weights=(0.2,) * pol.N_FEATURES)}
    solos = {}
    for tid, p in policies.items():
        mixed.add_tenant(tid, policy=p)
        solos[tid] = ControlPlane(**mk)
        solos[tid].add_tenant(tid, policy=p)
    rng = np.random.default_rng(5)
    t = 0.0
    for k in range(30):
        t += 1.0
        for i, tid in enumerate(policies):
            rate = 25.0 + 10.0 * i if k < 15 else 6.0  # phase change
            bts = _beats(rng, rate, t, 1.0)
            if bts:
                mixed.ingest([tid] * len(bts), bts)
                solos[tid].ingest([tid] * len(bts), bts)
        dec = mixed.tick()
        for tid in policies:
            solo = solos[tid].tick()
            np.testing.assert_allclose(
                dec["applied"][mixed.slot(tid)],
                solo["applied"][solos[tid].slot(tid)],
                rtol=1e-6, atol=1e-4, err_msg=f"{tid} period {k}")


def test_add_remove_leaves_survivor_state_untouched():
    plane = ControlPlane(profile="gros", dt=1.0)
    for i in range(3):
        plane.add_tenant(f"n{i}")
    rng = np.random.default_rng(6)
    t = 0.0
    for k in range(5):
        t += 1.0
        for i in range(3):
            bts = _beats(rng, 20.0, t, 1.0)
            plane.ingest([f"n{i}"] * len(bts), bts)
        plane.tick()
    s0, s2 = plane.slot("n0"), plane.slot("n2")
    keep = (plane._pstate[[s0, s2]].copy(),
            plane._pcap[[s0, s2]].copy(),
            plane.store.counts()[[s0, s2]].copy())
    victim = plane.slot("n1")
    plane.remove_tenant("n1")
    new_id = plane.add_tenant("n3", policy=DutyCyclePolicy())
    assert plane.slot("n3") == victim  # slot recycled
    np.testing.assert_array_equal(plane._pstate[[s0, s2]], keep[0])
    np.testing.assert_array_equal(plane._pcap[[s0, s2]], keep[1])
    np.testing.assert_array_equal(plane.store.counts()[[s0, s2]], keep[2])
    assert plane.n_tenants == 3 and new_id == "n3"
    with pytest.raises(KeyError):
        plane.slot("n1")
    with pytest.raises(ValueError, match="already registered"):
        plane.add_tenant("n0")


def test_capacity_grows_in_buckets_preserving_state():
    assert _bucket(1) == 16 and _bucket(17) == 32 and _bucket(100) == 128
    plane = ControlPlane(profile="gros", dt=1.0, capacity=16)
    plane.add_tenant("n0")
    plane.ingest(["n0"] * 3, [0.2, 0.5, 0.8])
    plane.tick()
    row = plane._pstate[plane.slot("n0")].copy()
    plane.add_tenants(40)
    assert plane.capacity == 64
    assert plane.n_tenants == 41
    np.testing.assert_array_equal(plane._pstate[plane.slot("n0")], row)
    plane.tick()  # still ticks at the new capacity


def test_chunked_tick_streams_and_matches_unchunked():
    a = ControlPlane(profile="gros", dt=1.0)
    b = ControlPlane(profile="gros", dt=1.0)
    ids = [f"n{i}" for i in range(20)]
    for p in (a, b):
        for tid in ids:
            p.add_tenant(tid)
    rng = np.random.default_rng(7)
    t, seen = 0.0, []
    for k in range(3):
        t += 1.0
        batch_ids, times = [], []
        for i, tid in enumerate(ids):
            bts = _beats(rng, 5.0 + i, t, 1.0)
            batch_ids += [tid] * len(bts)
            times += bts
        a.ingest(batch_ids, times)
        b.ingest(batch_ids, times)
        seen.clear()
        da = a.tick(chunk_size=8,
                    consume=lambda lo, hi, out: seen.append((lo, hi)))
        db = b.tick()
        assert seen[0][0] == 0 and seen[-1][1] == a.capacity
        for k_ in ("pcap", "applied", "phase_change", "progress"):
            np.testing.assert_array_equal(da[k_], db[k_], err_msg=k_)


# ---------------------------------------------------------------------------
# 3. snapshots: round-trip, tamper rejection, cross-process kill/resume
# ---------------------------------------------------------------------------

def _demo_plane():
    plane = ControlPlane(profile="gros", dt=1.0,
                         detector=DetectorConfig(threshold=8.0))
    plane.add_tenant("pi0")
    plane.add_tenant("dc0", policy=DutyCyclePolicy())
    plane.add_tenant("rls0", policy=PIPolicy(adaptive=RLSConfig()))
    return plane


def _drive(plane, n_ticks, k0):
    """Deterministic beats (function of tick index and slot only, no
    RNG) so two processes replay identical streams; returns the applied
    rows of the live slots, stacked over ticks."""
    out = []
    for k in range(k0, k0 + n_ticks):
        t = plane._t + 1.0
        ids, times = [], []
        for tid in ("pi0", "dc0", "rls0"):
            nb = 2 + (plane.slot(tid) + k) % 3
            ids += [tid] * nb
            times += [t - 1.0 + (j + 0.5) / nb for j in range(nb)]
        plane.ingest(ids, times)
        dec = plane.tick()
        out.append([dec["applied"][plane.slot(tid)]
                    for tid in ("pi0", "dc0", "rls0")])
    return np.asarray(out)


def test_snapshot_roundtrip_and_tamper_rejection():
    plane = _demo_plane()
    _drive(plane, 4, 0)
    snap = pickle.loads(pickle.dumps(plane.snapshot()))
    twin = ControlPlane.restore(snap)
    np.testing.assert_array_equal(_drive(plane, 4, 4), _drive(twin, 4, 4))
    bad = pickle.loads(pickle.dumps(plane.snapshot()))
    bad.pstate[0, 0] += 1.0
    with pytest.raises(ValueError, match="fingerprint"):
        ControlPlane.restore(bad)


def test_snapshot_kill_resume_across_processes(tmp_path):
    """The paper's NRM survives restarts via checkpointed state; the
    plane must too — restore in a FRESH process and continue the exact
    decision sequence of the uninterrupted plane."""
    plane = _demo_plane()
    _drive(plane, 4, 0)
    snap_path = tmp_path / "plane.pkl"
    with open(snap_path, "wb") as f:
        pickle.dump(plane.snapshot(), f)
    expect = _drive(plane, 4, 4)   # uninterrupted continuation
    script = textwrap.dedent("""
        import pickle, sys
        import numpy as np
        from repro.core.plane import ControlPlane

        with open(sys.argv[1], "rb") as f:
            plane = ControlPlane.restore(pickle.load(f))
        out = []
        for k in range(4, 8):
            t = plane._t + 1.0
            ids, times = [], []
            for tid in ("pi0", "dc0", "rls0"):
                nb = 2 + (plane.slot(tid) + k) % 3
                ids += [tid] * nb
                times += [t - 1.0 + (j + 0.5) / nb for j in range(nb)]
            plane.ingest(ids, times)
            dec = plane.tick()
            out.append([dec["applied"][plane.slot(tid)]
                        for tid in ("pi0", "dc0", "rls0")])
        np.save(sys.argv[2], np.asarray(out))
    """)
    out_path = tmp_path / "resumed.npy"
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath("src")
    subprocess.run([sys.executable, "-c", script, str(snap_path),
                    str(out_path)], check=True, env=env, timeout=240)
    np.testing.assert_array_equal(np.load(out_path), expect)


# ---------------------------------------------------------------------------
# 4. plane_step as a primitive
# ---------------------------------------------------------------------------

def test_plane_step_detector_mask_freezes_state():
    """det_on=0 must suppress the alarm AND freeze the detector state
    (a masked tenant re-enabling later starts where it left off, not
    from a half-accumulated statistic)."""
    prof = PROFILES["gros"]
    gains = PIGains.from_model(prof, 0.1)
    det_cfg = DetectorConfig(threshold=0.5, min_gap=0, drift=0.0)
    dv = detector_values(det_cfg, prof)
    ds0 = detect_init(dv, gains)
    vals = pol.policy_values(PIPolicy(), prof, gains)
    st = pol.policy_init(PIPolicy(), vals, gains)
    args = (gains, "pi", vals, st, float(prof.pcap_max),
            jnp.float32(0.0), jnp.float32(100.0), jnp.float32(1.0))
    _, ds_on, _, ch_on = plane_step(*args, det_vals=dv, det_state=ds0,
                                    det_on=jnp.float32(1.0))
    _, ds_off, _, ch_off = plane_step(*args, det_vals=dv, det_state=ds0,
                                      det_on=jnp.float32(0.0))
    assert float(ch_off) == 0.0
    np.testing.assert_array_equal(np.asarray(ds_off), np.asarray(ds0))
    assert not np.array_equal(np.asarray(ds_on), np.asarray(ds0))


def test_snapshot_nan_poisoned_state_rejected_despite_valid_hash():
    """The fingerprint only proves post-snapshot integrity; a plane that
    snapshotted already-diverged (NaN) rows hashes consistently, so
    restore must reject the payload itself — even when the attacker
    recomputes the digest over the poisoned rows."""
    plane = _demo_plane()
    _drive(plane, 3, 0)
    snap = pickle.loads(pickle.dumps(plane.snapshot()))
    snap.pstate[0, 0] = np.nan
    snap.fingerprint = snap.digest()  # internally consistent again
    with pytest.raises(ValueError, match="non-finite"):
        ControlPlane.restore(snap)
    snap2 = pickle.loads(pickle.dumps(plane.snapshot()))
    snap2.guard_state[1, 0] = np.inf
    snap2.fingerprint = snap2.digest()
    with pytest.raises(ValueError, match="non-finite"):
        ControlPlane.restore(snap2)


def test_guard_quarantine_leaves_other_tenants_bit_identical():
    """One tenant's telemetry goes dark: its guard must walk the
    HOLD -> FAILSAFE ladder (and show up in `quarantined()`) while
    every OTHER tenant's decision stream stays bit-for-bit the
    all-healthy plane's."""
    from repro.core import faults as flt
    mk = dict(profile="gros", dt=1.0,
              guard=flt.GuardConfig(hold_k=2, failsafe_k=5))
    healthy = ControlPlane(**mk)
    chaos = ControlPlane(**mk)
    ids = ["n0", "sick", "n2"]
    for p in (healthy, chaos):
        for tid in ids:
            p.add_tenant(tid)
    t = 0.0
    engaged = False
    for k in range(14):
        t += 1.0
        for p in (healthy, chaos):
            for tid in ids:
                if p is chaos and tid == "sick" and k >= 3:
                    continue  # the sick tenant's beats stop arriving
                nb = 4 + (k + len(tid)) % 3
                p.ingest([tid] * nb,
                         [t - 1.0 + (j + 0.5) / nb for j in range(nb)])
        dh = healthy.tick()
        dc = chaos.tick()
        for tid in ("n0", "n2"):
            sh, sc = healthy.slot(tid), chaos.slot(tid)
            for key in ("pcap", "applied", "progress"):
                np.testing.assert_array_equal(
                    dh[key][sh], dc[key][sc],
                    err_msg=f"{tid}/{key} tick {k}")
        if "guard_mode" in dc:
            engaged = engaged or \
                float(dc["guard_mode"][chaos.slot("sick")]) > 0
    assert engaged, "sick tenant's guard never engaged"
    assert chaos.quarantined() == ["sick"]
    assert healthy.quarantined() == []
    # recovery: beats resume, the quarantine clears
    t += 1.0
    chaos.ingest(["sick"] * 4,
                 [t - 1.0 + (j + 0.5) / 4 for j in range(4)])
    for tid in ("n0", "n2"):
        chaos.ingest([tid] * 4,
                     [t - 1.0 + (j + 0.5) / 4 for j in range(4)])
    chaos.tick()
    assert chaos.quarantined() == []


# ---------------------------------------------------------------------------
# detector-triggered re-identification (reexcite=) on the runtime path
# ---------------------------------------------------------------------------

def _reexcite_beats(n_steps, dt=1.0, flip=45):
    """One shared beat schedule (phase change at `flip`) so every arm
    sees identical workload input."""
    rng = np.random.default_rng(3)
    out, t = [], 0.0
    for k in range(n_steps):
        t += dt
        rate = 40.0 if k < flip else 8.0
        out.append(_beats(rng, rate, t, dt))
    return out


def _drive_reexcite(reexcite, beats, dt=1.0):
    nrm = NRM(PowerControlConfig(epsilon=0.1, plant_profile="gros",
                                 adaptive=True),
              detector=DetectorConfig(threshold=6.0, min_gap=5),
              reexcite=reexcite)
    caps, alarms = [], []
    for bts in beats:
        for bt in bts:
            nrm.heartbeat(t=bt)
        rec = nrm.control_step(dt=dt)
        caps.append(rec.pcap)
        alarms.append(rec.phase_change)
    return nrm, caps, alarms


def test_reexcite_probe_vs_covariance_reset_only():
    """S1 regression: post-alarm healthy windows get the short
    re-excitation recipe (policies.pi.reexcite_cap) on top of the
    engine-shared on_change covariance reset — vs the reset-only arm."""
    from repro.obs import events as evt
    beats = _reexcite_beats(90)
    base, caps0, al0 = _drive_reexcite(0, beats)
    rex, caps1, al1 = _drive_reexcite(4, beats)
    assert any(al0), "phase change never alarmed"
    first = al0.index(True)
    # bit-for-bit until the alarm: reexcite=0-equivalent before arming
    assert caps1[:first + 1] == caps0[:first + 1]
    assert al1.index(True) == first
    # the probe dithered the next healthy windows
    assert caps1[first + 1:first + 5] != caps0[first + 1:first + 5]
    probes = [e for e in rex.events.events()
              if e.code == evt.EV_REEXCITE]
    # the full budget ran (a later re-alarm may legitimately re-arm)
    assert len(probes) >= 4
    assert [int(e.payload[0]) for e in probes[:4]] == [1, 2, 3, 4]
    assert not [e for e in base.events.events()
                if e.code == evt.EV_REEXCITE]
    # excitation means information: the freshly-reset covariance must
    # contract at least as fast as staring at steady state does
    tr = lambda n: float(np.trace(np.asarray(n._rls_state.P)))
    assert tr(rex) <= tr(base) * 1.05


def test_reexcite_state_survives_checkpoint_round_trip():
    """Killing an NRM mid-probe must not restart (or drop) the dither:
    reexcite position rides state_dict like every other run state."""
    beats = _reexcite_beats(90)
    _, _, alarms = _drive_reexcite(4, beats)
    first = alarms.index(True)
    cut = first + 2  # mid-probe: 2 of 4 windows consumed

    nrm = NRM(PowerControlConfig(epsilon=0.1, plant_profile="gros",
                                 adaptive=True),
              detector=DetectorConfig(threshold=6.0, min_gap=5),
              reexcite=4)
    caps = []
    for bts in beats[:cut]:
        for bt in bts:
            nrm.heartbeat(t=bt)
        caps.append(nrm.control_step(dt=1.0).pcap)
    assert nrm._reexcite_left > 0, "cut landed outside the probe"
    d = pickle.loads(pickle.dumps(nrm.state_dict()))
    clone = NRM(PowerControlConfig(epsilon=0.1, plant_profile="gros",
                                   adaptive=True),
                detector=DetectorConfig(threshold=6.0, min_gap=5),
                reexcite=4)
    clone.load_state_dict(d)
    assert clone._reexcite_left == nrm._reexcite_left
    assert clone._reexcite_i == nrm._reexcite_i
    for bts in beats[cut:]:
        for bt in bts:
            nrm.heartbeat(t=bt)
            clone.heartbeat(t=bt)
        assert clone.control_step(dt=1.0).pcap \
            == nrm.control_step(dt=1.0).pcap
