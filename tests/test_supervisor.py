"""Durable campaign supervisor (repro.core.supervisor) + the shared
retry ladder and the HTTP push sink it rides with.

The load-bearing property, inherited from the executor contract: every
run's parameters and RNG ride in its own row, so a campaign that was
retried, timed out, quarantined, killed -9 and resumed produces results
bit-for-bit identical to one uninterrupted `run_grid` call.
"""
import http.server
import json
import os
import pickle
import signal
import subprocess
import sys
import threading
from pathlib import Path

import numpy as np
import pytest

from repro.core import executor, supervisor
from repro.obs.retry import RetryPolicy, call_with_retries

N = 20
CHUNK = 4


def _toy(b, c):
    return {"y": b["x"] * c, "z": b["x"] + 1.0}


def _grid(n=N):
    import jax.numpy as jnp
    return {"x": np.arange(n, dtype=np.float32)}, (jnp.float32(2.0),)


def _reference(n=N, chunk=CHUNK):
    batched, shared = _grid(n)
    merged, _ = executor.run_grid(_toy, batched, shared, n,
                                  chunk_size=chunk)
    return merged


def _assert_identical(a, b):
    assert set(a) == set(b)
    for k in a:
        np.testing.assert_array_equal(np.asarray(a[k]),
                                      np.asarray(b[k]), err_msg=k)


# ---------------------------------------------------------------- retry
def test_retry_policy_backoff_ladder():
    p = RetryPolicy(max_retries=5, base_s=0.1, factor=2.0, max_s=0.5,
                    jitter=0.25)
    assert [p.backoff_s(a) for a in range(4)] == [0.1, 0.2, 0.4, 0.5]
    import random
    rng = random.Random(0)
    for a in range(4):
        d = p.backoff_s(a, rng)
        base = min(0.1 * 2.0 ** a, 0.5)
        assert 0.75 * base <= d <= 1.25 * base


def test_call_with_retries_budget_and_hook():
    calls, seen = [], []
    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise OSError("transient")
        return "ok"
    out = call_with_retries(flaky, RetryPolicy(max_retries=3, base_s=0.0),
                            on_retry=lambda a, d, e: seen.append(a),
                            sleep=lambda s: None)
    assert out == "ok" and len(calls) == 3 and seen == [0, 1]
    with pytest.raises(ValueError):
        call_with_retries(lambda: (_ for _ in ()).throw(ValueError("x")),
                          RetryPolicy(max_retries=2, base_s=0.0),
                          sleep=lambda s: None)


def test_classify_failure_rungs():
    cf = supervisor.classify_failure
    assert cf(supervisor.DeviceLost(1)) == "device"
    assert cf(supervisor.ChunkTimeout("t")) == "timeout"
    assert cf(supervisor.TransientFault("f")) == "transient"
    assert cf(MemoryError()) == "transient"
    assert cf(RuntimeError("RESOURCE_EXHAUSTED: out of memory")) \
        == "transient"
    assert cf(RuntimeError("device lost mid-collective")) == "device"
    assert cf(ValueError("shapes do not match")) == "permanent"


# -------------------------------------------------------------- journal
def test_journal_roundtrip_and_torn_tail(tmp_path):
    p = tmp_path / "j.jsonl"
    j = supervisor.Journal(p)
    recs = [{"k": "plan", "fp": "a"}, {"k": "commit", "ci": 0},
            {"k": "commit", "ci": 1}]
    for r in recs:
        j.append(r)
    j.close()
    got, torn = supervisor.read_journal(p)
    assert got == recs and torn == 0
    # torn tail: chop the last record mid-line — dropped, counted
    raw = p.read_bytes()
    p.write_bytes(raw[:-9])
    got, torn = supervisor.read_journal(p)
    assert got == recs[:2] and torn == 1
    # corruption that is NOT the tail refuses to resume
    lines = raw.decode().splitlines()
    lines[1] = lines[1][:-4] + 'xx"}'
    p.write_text("\n".join(lines) + "\n")
    with pytest.raises(ValueError, match="corrupt"):
        supervisor.read_journal(p)


def test_durable_matches_bare_run_grid(tmp_path):
    batched, shared = _grid()
    merged, report = supervisor.run_durable(
        _toy, batched, shared, N, dir=tmp_path, chunk_size=CHUNK)
    _assert_identical(merged, _reference())
    assert report.n_chunks == 5 and report.committed == 5
    assert not report.dead and not report.resumed and report.retries == 0
    recs, torn = supervisor.read_journal(tmp_path
                                         / supervisor.JOURNAL_NAME)
    kinds = [r["k"] for r in recs]
    assert kinds[0] == "plan" and kinds[-1] == "done" and torn == 0
    assert kinds.count("commit") == 5 and kinds.count("start") == 5
    assert (tmp_path / supervisor.CHECKPOINT_NAME).exists()


def test_transient_faults_retry_to_identical_completion(tmp_path):
    """10%-style chunk chaos: injected transient faults retry with
    backoff and the campaign completes with zero lost runs."""
    batched, shared = _grid()
    flaky = supervisor.FlakyGridFn(
        _toy, failures={0: supervisor.TransientFault("injected"),
                        3: RuntimeError("RESOURCE_EXHAUSTED: pool")})
    cfg = supervisor.CampaignConfig(
        retry=RetryPolicy(max_retries=3, base_s=0.001, max_s=0.01))
    merged, report = supervisor.run_durable(
        flaky, batched, shared, N, dir=tmp_path, chunk_size=CHUNK,
        wrap="none", config=cfg)
    _assert_identical(merged, _reference())
    assert report.retries == 2 and not report.dead
    recs, _ = supervisor.read_journal(tmp_path / supervisor.JOURNAL_NAME)
    retries = [r for r in recs if r["k"] == "retry"]
    assert {r["reason"] for r in retries} == {"transient"}


def test_permanent_failure_dead_letters_and_campaign_continues(tmp_path):
    batched, shared = _grid()
    flaky = supervisor.FlakyGridFn(
        _toy, failures={1: ValueError("bad shapes")})
    merged, report = supervisor.run_durable(
        flaky, batched, shared, N, dir=tmp_path, chunk_size=CHUNK,
        wrap="none")
    assert [ci for ci, _ in report.dead] == [1]
    assert "bad shapes" in report.dead[0][1]
    ref = _reference()
    for k in ref:
        got, want = np.asarray(merged[k]), np.asarray(ref[k])
        np.testing.assert_array_equal(got[:CHUNK], want[:CHUNK])
        np.testing.assert_array_equal(got[2 * CHUNK:], want[2 * CHUNK:])


def test_retry_budget_exhaustion_dead_letters(tmp_path):
    batched, shared = _grid()
    fails = {i: supervisor.TransientFault(f"attempt {i}")
             for i in range(3)}  # chunk 0 faults on every attempt
    cfg = supervisor.CampaignConfig(
        retry=RetryPolicy(max_retries=2, base_s=0.001, max_s=0.01))
    flaky = supervisor.FlakyGridFn(_toy, failures=fails)
    merged, report = supervisor.run_durable(
        flaky, batched, shared, N, dir=tmp_path, chunk_size=CHUNK,
        wrap="none", config=cfg)
    assert [ci for ci, _ in report.dead] == [0]
    assert report.retries == 2


def test_watchdog_timeout_retries_bit_identical(tmp_path):
    batched, shared = _grid()
    flaky = supervisor.FlakyGridFn(_toy, delays={0: 2.0})
    cfg = supervisor.CampaignConfig(
        chunk_timeout_s=0.25,
        retry=RetryPolicy(max_retries=2, base_s=0.001, max_s=0.01))
    merged, report = supervisor.run_durable(
        flaky, batched, shared, N, dir=tmp_path, chunk_size=CHUNK,
        wrap="none", config=cfg)
    _assert_identical(merged, _reference())
    assert report.retries >= 1 and not report.dead
    recs, _ = supervisor.read_journal(tmp_path / supervisor.JOURNAL_NAME)
    assert any(r["k"] == "retry" and r["reason"] == "timeout"
               for r in recs)


def test_fingerprint_mismatch_rejected(tmp_path):
    batched, shared = _grid()
    supervisor.run_durable(_toy, batched, shared, N, dir=tmp_path,
                           chunk_size=CHUNK)
    other = {"x": np.arange(N, dtype=np.float32) + 1.0}
    with pytest.raises(ValueError, match="planned for grid"):
        supervisor.run_durable(_toy, other, shared, N, dir=tmp_path,
                               chunk_size=CHUNK)


def test_resume_finished_campaign_returns_checkpoint(tmp_path):
    batched, shared = _grid()
    supervisor.run_durable(_toy, batched, shared, N, dir=tmp_path,
                           chunk_size=CHUNK)
    flaky = supervisor.FlakyGridFn(_toy)  # counts calls
    merged, report = supervisor.run_durable(
        flaky, batched, shared, N, dir=tmp_path, chunk_size=CHUNK,
        wrap="none")
    _assert_identical(merged, _reference())
    assert report.resumed and report.committed == 0
    assert flaky.calls == 0  # nothing recomputed: checkpoint was final


def test_torn_tail_replays_chunk_bit_identical(tmp_path):
    """S4 torn-write: truncate the journal mid-record and drop the
    checkpoint — the partial record is discarded (counted) and the
    affected chunks recompute to the identical merge."""
    batched, shared = _grid()
    supervisor.run_durable(_toy, batched, shared, N, dir=tmp_path,
                           chunk_size=CHUNK)
    jpath = tmp_path / supervisor.JOURNAL_NAME
    raw = jpath.read_bytes()
    jpath.write_bytes(raw[:-10])  # tear the terminal record
    (tmp_path / supervisor.CHECKPOINT_NAME).unlink()
    merged, report = supervisor.run_durable(
        _toy, batched, shared, N, dir=tmp_path, chunk_size=CHUNK)
    _assert_identical(merged, _reference())
    assert report.resumed and report.torn_records == 1
    assert report.replayed >= 1  # checkpointless commits recomputed


def test_consume_mode_journal_is_authoritative(tmp_path):
    """Committed chunks are never re-delivered to a consume hook on
    resume — the journal, not the checkpoint, is the source of truth."""
    batched, shared = _grid()
    first, second = [], []
    supervisor.run_durable(_toy, batched, shared, N, dir=tmp_path,
                           chunk_size=CHUNK,
                           consume=lambda lo, hi, out:
                           first.append((lo, hi)))
    assert first == [(0, 4), (4, 8), (8, 12), (12, 16), (16, 20)]
    merged, report = supervisor.run_durable(
        _toy, batched, shared, N, dir=tmp_path, chunk_size=CHUNK,
        consume=lambda lo, hi, out: second.append((lo, hi)))
    assert merged is None and report.resumed and second == []


def test_campaign_events_stream_to_disk(tmp_path):
    batched, shared = _grid()
    flaky = supervisor.FlakyGridFn(
        _toy, failures={0: supervisor.TransientFault("x")})
    cfg = supervisor.CampaignConfig(
        retry=RetryPolicy(max_retries=2, base_s=0.001, max_s=0.01))
    supervisor.run_durable(flaky, batched, shared, N, dir=tmp_path,
                           chunk_size=CHUNK, wrap="none", config=cfg)
    from repro.obs import events as evt
    rows = [json.loads(ln) for ln in
            (tmp_path / supervisor.EVENTS_NAME).read_text().splitlines()]
    assert any(int(r["code"]) == evt.EV_CHUNK_RETRY for r in rows)
    assert all(int(r["source"]) == evt.SRC_SUPERVISOR for r in rows)


def test_supervisor_metrics_published(tmp_path):
    from repro.obs import metrics as obs_metrics
    batched, shared = _grid()
    flaky = supervisor.FlakyGridFn(
        _toy, failures={0: supervisor.TransientFault("x"),
                        2: ValueError("perm")})
    cfg = supervisor.CampaignConfig(
        retry=RetryPolicy(max_retries=2, base_s=0.001, max_s=0.01))
    reg = obs_metrics.get_registry()
    before = reg.counter("supervisor_retries_total",
                         labelnames=("reason",)
                         ).value(reason="transient")
    supervisor.run_durable(flaky, batched, shared, N, dir=tmp_path,
                           chunk_size=CHUNK, wrap="none", config=cfg)
    assert reg.counter("supervisor_retries_total",
                       labelnames=("reason",)
                       ).value(reason="transient") == before + 1
    snap = reg.snapshot()["metrics"]
    assert "supervisor_dead_letter_total" in snap
    assert "supervisor_backoff_seconds" in snap
    assert "supervisor_faults_injected_total" in snap


# --------------------------------------------------------- crash safety
def _sub_env(n_devices=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(Path(__file__).resolve().parents[1] / "src")
    if n_devices:
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                            + f" --xla_force_host_platform_device_count"
                              f"={n_devices}")
    return env


_CHILD_TOY = """
import numpy as np, jax.numpy as jnp
from repro.core import supervisor
x = np.arange(24, dtype=np.float32)
def toy(b, c):
    return {{"y": b["x"] * c, "z": b["x"] + 1.0}}
cfg = supervisor.CampaignConfig(checkpoint_every=2, kill_after_commits=3,
                                kill_signal={sig})
supervisor.run_durable(toy, {{"x": x}}, (jnp.float32(2.0),), 24,
                       dir={dir!r}, chunk_size=4, config=cfg)
print("SURVIVED_KILL")
"""


@pytest.mark.parametrize("sig", [signal.SIGKILL, signal.SIGTERM],
                         ids=["kill9", "sigterm"])
def test_kill_mid_campaign_then_resume_bit_identical(tmp_path, sig):
    """S4: kill -9 (and SIGTERM) right after an fsync'd commit; the
    reopened campaign replays exactly the uncommitted chunks and the
    merge equals the uninterrupted run bit-for-bit."""
    code = _CHILD_TOY.format(sig=int(sig), dir=str(tmp_path))
    out = subprocess.run([sys.executable, "-c", code], env=_sub_env(),
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == -int(sig), out.stdout + out.stderr
    assert "SURVIVED_KILL" not in out.stdout

    import jax.numpy as jnp
    x = np.arange(24, dtype=np.float32)
    batched, shared = {"x": x}, (jnp.float32(2.0),)
    ref, _ = executor.run_grid(_toy, batched, shared, 24, chunk_size=4)
    merged, report = supervisor.run_durable(
        _toy, batched, shared, 24, dir=tmp_path, chunk_size=4)
    _assert_identical(merged, ref)
    assert report.resumed and not report.dead
    # kill landed after commit 3 with checkpoint cadence 2: one commit
    # was journaled but not yet snapshotted -> recomputed on resume
    assert report.replayed == 1


def test_quarantine_and_reinstate_two_devices(tmp_path):
    """DeviceLost quarantines the named shard, the campaign degrades to
    the surviving set, probes the device back in after clean commits,
    and still merges bit-identically. 2 forced host CPU devices."""
    code = f"""
import numpy as np, jax.numpy as jnp, jax
from repro.core import executor, supervisor
from repro.obs.retry import RetryPolicy
assert len(jax.local_devices()) == 2
x = np.arange(24, dtype=np.float32)
def toy(b, c):
    return {{"y": b["x"] * c}}
batched, shared = {{"x": x}}, (jnp.float32(2.0),)
ref, _ = executor.run_grid(toy, batched, shared, 24, chunk_size=4)
flaky = supervisor.FlakyGridFn(
    toy, failures={{2: supervisor.DeviceLost(device_id=1)}})
cfg = supervisor.CampaignConfig(
    probe_after=2, retry=RetryPolicy(max_retries=2, base_s=0.001))
merged, report = supervisor.run_durable(
    flaky, batched, shared, 24, dir={str(tmp_path)!r}, chunk_size=4,
    devices="all", wrap="none", config=cfg)
np.testing.assert_array_equal(np.asarray(merged["y"]),
                              np.asarray(ref["y"]))
assert report.reinstated == [1], report
assert report.quarantined == [], report
assert not report.dead and report.retries == 1, report
recs, _ = supervisor.read_journal(
    "{tmp_path}/" + supervisor.JOURNAL_NAME)
kinds = [r["k"] for r in recs]
assert "quarantine" in kinds and "reinstate" in kinds
print("QUARANTINE_OK")
"""
    out = subprocess.run([sys.executable, "-c", code],
                         env=_sub_env(n_devices=2),
                         capture_output=True, text=True, timeout=600)
    assert "QUARANTINE_OK" in out.stdout, out.stdout + out.stderr


# ------------------------------------------------- sweep/fleet/harvest
SWEEP_KW = dict(total_work=300.0, max_time=256.0, collect_traces=False)


def test_sweep_durable_matches_plain_and_resumes(tmp_path):
    from repro.core.sim import sweep
    one = sweep("gros", [0.1, 0.3], range(4), **SWEEP_KW)
    dur = sweep("gros", [0.1, 0.3], range(4), chunk_size=3,
                durable=tmp_path, **SWEEP_KW)
    np.testing.assert_array_equal(np.asarray(one.exec_time),
                                  np.asarray(dur.exec_time))
    np.testing.assert_array_equal(np.asarray(one.energy),
                                  np.asarray(dur.energy))
    np.testing.assert_array_equal(
        np.asarray(one.summary["progress_hist"]),
        np.asarray(dur.summary["progress_hist"]))
    # the saved spec re-dispatches through the finished journal
    res = supervisor.resume_campaign(tmp_path)
    np.testing.assert_array_equal(np.asarray(one.exec_time),
                                  np.asarray(res.exec_time))


def test_sweep_kill9_then_resume_campaign_bit_identical(tmp_path):
    """The acceptance scenario end to end: a durable sweep killed -9
    mid-campaign, then `resume_campaign(dir)` alone (the spec carries
    everything) reproduces the uninterrupted SweepResult bit-for-bit."""
    code = f"""
from repro.core.sim import sweep
from repro.core.supervisor import CampaignConfig
sweep("gros", [0.1, 0.3], range(6), total_work=300.0, max_time=256.0,
      collect_traces=False, chunk_size=3, durable={str(tmp_path)!r},
      campaign=CampaignConfig(checkpoint_every=1, kill_after_commits=2))
print("SURVIVED_KILL")
"""
    out = subprocess.run([sys.executable, "-c", code], env=_sub_env(),
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == -int(signal.SIGKILL), \
        out.stdout + out.stderr

    res = supervisor.resume_campaign(tmp_path)
    from repro.core.sim import sweep
    one = sweep("gros", [0.1, 0.3], range(6), **SWEEP_KW)
    np.testing.assert_array_equal(np.asarray(one.exec_time),
                                  np.asarray(res.exec_time))
    np.testing.assert_array_equal(np.asarray(one.energy),
                                  np.asarray(res.energy))
    for k in ("progress_mean", "power_mean", "progress_hist"):
        np.testing.assert_array_equal(np.asarray(one.summary[k]),
                                      np.asarray(res.summary[k]),
                                      err_msg=k)
    # the spec was sanitized: the resume must NOT inherit the chaos
    # injector that killed the first process
    with open(Path(tmp_path) / supervisor.SPEC_NAME, "rb") as fh:
        spec = pickle.load(fh)
    assert spec["kwargs"]["campaign"].kill_after_commits is None


def test_fleet_sweep_durable_matches_plain(tmp_path):
    from repro.core.hierarchy import FleetConfig, fleet_sweep
    from repro.core.plant import PROFILES
    prof = PROFILES["dahu"]
    peak = float(prof.power_of_pcap(prof.pcap_max)) * 8
    fc = FleetConfig(n_nodes=8, epsilon=0.1, power_budget=0.7 * peak)
    fs = fleet_sweep(prof, fc, steps=25, seeds=[0, 1, 2], chunk_size=2)
    fd = fleet_sweep(prof, fc, steps=25, seeds=[0, 1, 2], chunk_size=2,
                     durable=tmp_path)
    np.testing.assert_array_equal(np.asarray(fs["power"]),
                                  np.asarray(fd["power"]))
    np.testing.assert_array_equal(np.asarray(fs["energy_total"]),
                                  np.asarray(fd["energy_total"]))
    assert (Path(tmp_path) / supervisor.SPEC_NAME).exists()


def test_harvest_dataset_durable_spools_parts(tmp_path):
    from repro.core.policies.offline_rl import harvest_dataset
    plain = harvest_dataset("gros", [0.1], range(2), total_work=300.0,
                            max_time=256.0, chunk_size=1)
    dur = harvest_dataset("gros", [0.1], range(2), total_work=300.0,
                          max_time=256.0, chunk_size=1,
                          durable=tmp_path)
    for k in ("s", "a", "r", "s2"):
        np.testing.assert_array_equal(plain[k], dur[k], err_msg=k)
    parts = sorted((Path(tmp_path) / "parts").glob("part_*.npz"))
    assert len(parts) == 2  # one atomic spool file per chunk


def test_resume_campaign_requires_spec(tmp_path):
    with pytest.raises(FileNotFoundError, match="campaign spec"):
        supervisor.resume_campaign(tmp_path)


# ------------------------------------------------------------ push sink
class _GatewayHandler(http.server.BaseHTTPRequestHandler):
    fail_first = 2
    posts = []
    bodies = []

    def do_POST(self):
        cls = _GatewayHandler
        n = int(self.headers.get("Content-Length", 0))
        body = self.rfile.read(n)
        cls.posts.append(self.path)
        if len(cls.posts) <= cls.fail_first:
            self.send_response(503)
            self.end_headers()
            return
        cls.bodies.append(body)
        self.send_response(200)
        self.end_headers()

    def log_message(self, *a):  # quiet
        pass


@pytest.fixture
def gateway():
    _GatewayHandler.posts, _GatewayHandler.bodies = [], []
    srv = http.server.HTTPServer(("127.0.0.1", 0), _GatewayHandler)
    th = threading.Thread(target=srv.serve_forever, daemon=True)
    th.start()
    yield f"http://127.0.0.1:{srv.server_address[1]}/push"
    srv.shutdown()
    th.join(timeout=5)


def test_push_sink_retries_through_failing_gateway(gateway):
    """The acceptance harness: a stdlib HTTP handler fails the first N
    posts; the retry ladder delivers every row anyway."""
    from repro.obs.sink import PushSink
    _GatewayHandler.fail_first = 2
    sink = PushSink(gateway, batch=64,
                    policy=RetryPolicy(max_retries=4, base_s=0.01),
                    sleep=lambda s: None)
    rows = [{"i": i, "v": float(i) * 0.5} for i in range(10)]
    sink.write_many(rows)
    assert len(sink) == 10  # nothing sent until flush
    sink.flush()
    assert len(sink) == 0 and sink.pushed == 10 and sink.errors == 0
    assert len(_GatewayHandler.posts) == 3  # 2 failures + 1 success
    got = [json.loads(ln) for ln in
           _GatewayHandler.bodies[0].decode().splitlines()]
    assert got == rows


def test_push_sink_swallows_exhausted_errors_and_respools():
    from repro.obs.sink import PushSink

    def dead_post(url, data, timeout):
        raise OSError("gateway down")

    sink = PushSink("http://x/push", max_spool=8, batch=4,
                    policy=RetryPolicy(max_retries=1, base_s=0.0),
                    post=dead_post, sleep=lambda s: None)
    for i in range(6):
        sink.write({"i": i})
    sink.flush()  # must not raise
    assert sink.errors == 1 and sink.pushed == 0
    assert len(sink) == 6  # batch re-spooled at the front, none lost


def test_push_sink_bounded_spool_drops_oldest():
    from repro.obs.sink import PushSink
    seen = []
    sink = PushSink("http://x/push", max_spool=4, batch=16,
                    post=lambda u, d, t: seen.append(d))
    for i in range(7):
        sink.write({"i": i})
    assert sink.dropped == 3
    sink.flush()
    got = [json.loads(ln) for ln in seen[0].decode().splitlines()]
    assert [r["i"] for r in got] == [3, 4, 5, 6]
